"""Data-pipeline determinism + tier-movement semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.offload import (DEVICE, PINNED_HOST, backend_memory_kinds,
                                put_tier, tier_of, tree_put_tier, nbytes_of)
from repro.data.pipeline import DataConfig, SyntheticLM, make_dataset


class TestData:
    def test_deterministic_in_step(self):
        cfg = DataConfig(vocab_size=128, seq_len=32, global_batch=4, seed=3)
        ds = SyntheticLM(cfg)
        a, b = ds.batch(7), ds.batch(7)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        c = ds.batch(8)
        assert not np.array_equal(a["tokens"], c["tokens"])

    def test_host_sharding_partitions_batch(self):
        """Two hosts' shards at the same step are disjoint deterministic
        streams, each carrying its slice of the global batch."""
        h0 = SyntheticLM(DataConfig(128, 32, 4, seed=3, shard=(0, 2)))
        h1 = SyntheticLM(DataConfig(128, 32, 4, seed=3, shard=(1, 2)))
        assert h0.batch(5)["tokens"].shape[0] == 2
        assert h1.batch(5)["tokens"].shape[0] == 2
        assert not np.array_equal(h0.batch(5)["tokens"],
                                  h1.batch(5)["tokens"])

    def test_labels_are_shifted_tokens(self):
        ds = SyntheticLM(DataConfig(128, 16, 2, seed=0))
        b = ds.batch(0)
        # learnable structure: ~90% of successors follow the chain
        succ = ds._succ
        match = (succ[b["tokens"][:, :-1]] == b["tokens"][:, 1:]).mean()
        assert match > 0.7

    def test_token_file_backend(self, tmp_path):
        path = str(tmp_path / "toks.bin")
        np.arange(10_000, dtype=np.int32).tofile(path)
        cfg = DataConfig(vocab_size=1 << 20, seq_len=64, global_batch=2)
        ds = make_dataset(cfg, path)
        b = ds.batch(0)
        assert b["tokens"].shape == (2, 64)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


class TestTiers:
    def test_put_tier_roundtrip(self):
        if PINNED_HOST not in backend_memory_kinds():
            pytest.skip("no host memory kinds on this backend")
        x = jnp.arange(16.0).reshape(4, 4)
        h = put_tier(x, PINNED_HOST)
        assert tier_of(h) == PINNED_HOST
        d = put_tier(h, DEVICE)
        assert tier_of(d) == DEVICE
        np.testing.assert_array_equal(np.asarray(d), np.asarray(x))

    def test_host_slice_cleared_to_device(self):
        """Slices of host arrays must come back fully device-spaced (the
        JAX 0.8 sticky-<host>-aval quirk regression test)."""
        if PINNED_HOST not in backend_memory_kinds():
            pytest.skip("no host memory kinds")
        pool = put_tier(jnp.zeros((4, 2, 2)), PINNED_HOST)
        y = put_tier(pool[1], DEVICE)
        # mixing into dynamic_update_slice must not raise
        out = jax.lax.dynamic_update_slice(jnp.ones((2, 2)), y, (0, 0))
        assert float(out.sum()) == 0.0

    def test_tree_put_tier_and_nbytes(self):
        tree = {"a": jnp.zeros((8,), jnp.float32),
                "b": jnp.zeros((2, 2), jnp.bfloat16)}
        assert nbytes_of(tree) == 32 + 8
        if PINNED_HOST in backend_memory_kinds():
            ht = tree_put_tier(tree, PINNED_HOST)
            assert all(tier_of(l) == PINNED_HOST
                       for l in jax.tree_util.tree_leaves(ht))
