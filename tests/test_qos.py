"""repro.qos: link arbitration, congestion model, SLO admission.

Pins the ISSUE-1 properties: conservation (goodput never exceeds the
link), weighted fairness (equal weights split within 10% under
saturation; 2:1 weight -> ~2x), and SLO-admission monotonicity (adding
tenants never improves an incumbent's modeled p99).
"""

import numpy as np
import pytest

from repro.core import congested_latency, make_default_fabric
from repro.core.fabric import DeviceClass, DeviceInfo
from repro.qos import (AdmissionController, Decision, LinkArbiter, LinkState,
                       ContendedTierSpec, SLOTarget, jain_fairness,
                       weighted_max_min)
from repro.core.tiers import TierKind, paper_tiers


# ------------------------------------------------------------ water-filling
def test_allocation_conservation():
    """Sum of grants never exceeds capacity, and no grant exceeds demand."""
    rng = np.random.default_rng(0)
    for _ in range(50):
        n = int(rng.integers(1, 12))
        demands = {f"t{i}": float(rng.uniform(0, 20e9)) for i in range(n)}
        weights = {f"t{i}": float(rng.uniform(0.1, 4.0)) for i in range(n)}
        cap = float(rng.uniform(1e9, 40e9))
        grants = weighted_max_min(demands, weights, cap)
        assert sum(grants.values()) <= cap * (1 + 1e-9)
        for t, g in grants.items():
            assert g <= demands[t] + 1e-6


def test_equal_weight_fairness_under_saturation():
    """Equal-weight tenants demanding > fair share split within 10%."""
    n, cap = 8, 30e9
    demands = {f"t{i}": cap for i in range(n)}     # everyone saturates
    weights = {f"t{i}": 1.0 for i in range(n)}
    grants = weighted_max_min(demands, weights, cap)
    shares = list(grants.values())
    assert max(shares) <= 1.10 * min(shares)
    assert jain_fairness(grants) > 0.99
    assert sum(shares) == pytest.approx(cap, rel=1e-6)


def test_weighted_share_2x():
    """A 2:1-weighted tenant gets ~2x an unweighted one when saturated."""
    cap = 30e9
    demands = {f"t{i}": cap for i in range(8)}
    weights = {f"t{i}": (2.0 if i == 0 else 1.0) for i in range(8)}
    grants = weighted_max_min(demands, weights, cap)
    assert grants["t0"] == pytest.approx(2.0 * grants["t1"], rel=1e-6)


def test_unsaturated_tenant_fully_satisfied():
    grants = weighted_max_min({"small": 1e9, "big": 100e9},
                              {"small": 1.0, "big": 1.0}, 10e9)
    assert grants["small"] == pytest.approx(1e9)
    assert grants["big"] == pytest.approx(9e9)


# ----------------------------------------------------------------- arbiter
def test_arbiter_meter_conservation():
    """Metered goodput across tenants never exceeds link bandwidth."""
    arb = LinkArbiter(1e9)
    for t in ("a", "b", "c"):
        arb.register(t)
    rng = np.random.default_rng(1)
    total = 0
    for _ in range(300):
        t = ("a", "b", "c")[int(rng.integers(0, 3))]
        nbytes = int(rng.integers(1 << 10, 1 << 20))
        total += nbytes
        arb.meter(t, nbytes)
    snap = arb.snapshot()
    goodput = sum(arb.goodput_Bps(t) for t in ("a", "b", "c"))
    assert goodput <= 1e9 * (1 + 1e-9)
    assert snap["utilization_cumulative"] == pytest.approx(1.0)


def test_arbiter_token_bucket_burst_then_wait():
    """A full bucket absorbs a burst instantly; a drained one waits for
    refill at the tenant's *fair* rate (half the link here), which is
    slower than the wire."""
    arb = LinkArbiter(1e9)
    arb.register("t", weight=1.0, burst_bytes=1 << 20)
    arb.register("other", weight=1.0)       # halves t's refill rate
    g1 = arb.meter("t", 1 << 20)            # rides the burst credit
    assert g1.start_s == pytest.approx(0.0)
    g2 = arb.meter("t", 1 << 20)            # bucket empty: waits for refill
    assert g2.start_s > g1.completion_s


def test_arbiter_utilization_direction():
    """EWMA utilization reads high for a backlogged link, low for a
    sparse one (regression: an earlier draft had this inverted)."""
    sat = LinkArbiter(1e9)
    sat.register("t")
    for _ in range(50):
        sat.meter("t", 1 << 20)          # back-to-back: fully queued
    idle = LinkArbiter(1e9)
    idle.register("t")
    for i in range(50):
        idle.meter("t", 1 << 20, now_s=float(i))   # 1 MB/s on a 1 GB/s link
    assert sat.utilization() > 0.9
    assert idle.utilization() < 0.1
    assert sat.utilization() > idle.utilization()


def test_arbiter_unknown_tenant():
    arb = LinkArbiter(1e9)
    from repro.qos import UnknownTenant
    with pytest.raises(UnknownTenant):
        arb.meter("ghost", 1024)


# ------------------------------------------------------------- contention
def test_congested_latency_monotone_and_uncontended_floor():
    base = 190e-9
    assert congested_latency(base, 0.0) == base
    last = 0.0
    for rho in np.linspace(0, 1.2, 25):
        cur = congested_latency(base, float(rho))
        assert cur >= last
        last = cur
    assert np.isfinite(congested_latency(base, 10.0))


def test_contended_tier_tracks_link_state():
    spec = paper_tiers()[TierKind.LMB_CXL]
    link = LinkState(link_bandwidth_Bps=30e9)
    ct = ContendedTierSpec(spec, link)
    idle = ct.access_time(4096)
    assert idle == pytest.approx(spec.access_time(4096))
    link.set_demand(27e9)                    # 90% utilization
    assert ct.access_time(4096) > idle
    assert ct.added_latency_s > spec.added_latency_s


# ------------------------------------------------------------------- SLO
def test_slo_admission_monotonicity():
    """Adding tenants never improves an incumbent's modeled p99."""
    ctrl = AdmissionController(link_bandwidth_Bps=10e9)
    ctrl.register("incumbent", target=SLOTarget(p99_latency_s=1.0),
                  demand_Bps=2e9, base_latency_s=1e-3)
    assert ctrl.decide("incumbent") is Decision.ADMIT
    last = ctrl.modeled_p99("incumbent")
    for i in range(8):
        ctrl.register(f"n{i}", target=SLOTarget(p99_latency_s=10.0),
                      demand_Bps=1e9, base_latency_s=1e-3)
        ctrl.decide(f"n{i}")
        cur = ctrl.modeled_p99("incumbent")
        assert cur >= last - 1e-15, (i, cur, last)
        last = cur
    assert last > ctrl.tenant("incumbent").base_latency_s


def test_slo_admit_throttle_shed_bands():
    ctrl = AdmissionController(link_bandwidth_Bps=10e9)
    base = 1e-3
    # empty link: modeled p99 == base -> admit
    ctrl.register("ok", target=SLOTarget(p99_latency_s=base * 2),
                  demand_Bps=1e9, base_latency_s=base)
    assert ctrl.decide("ok") is Decision.ADMIT
    # hog pushes utilization to ~1: everyone's queue model blows up
    ctrl.register("hog", target=SLOTarget(p99_latency_s=100.0),
                  demand_Bps=9e9, base_latency_s=base)
    assert ctrl.decide("hog") is Decision.ADMIT
    # newcomer with a tight target on a saturated link is shed
    ctrl.register("late", target=SLOTarget(p99_latency_s=base * 1.5,
                                           shed_factor=2.0),
                  demand_Bps=1e9, base_latency_s=base)
    assert ctrl.decide("late") is Decision.SHED
    # ... and releasing load re-opens the door
    ctrl.release("hog")
    assert ctrl.decide("late") in (Decision.ADMIT, Decision.THROTTLE)


def test_slo_observed_latency_raises_floor():
    ctrl = AdmissionController(link_bandwidth_Bps=10e9)
    ctrl.register("t", target=SLOTarget(p99_latency_s=1.0),
                  demand_Bps=0.0, base_latency_s=1e-3)
    p_before = ctrl.modeled_p99("t")
    for _ in range(50):
        ctrl.observe("t", 0.5)
    assert ctrl.modeled_p99("t") >= 0.5 > p_before


# ----------------------------------------------- FM + LinkedBuffer wiring
def test_fabric_meters_linked_buffer_traffic():
    """Paging traffic shows up as link occupancy on the FM's arbiter."""
    jnp = pytest.importorskip("jax.numpy")
    from repro.core import system_for
    system = system_for("d0", host_id="h0", pool_gib=1, page_bytes=4096)
    fm = system.fm
    buf = system.buffer(name="t", device_id="d0",
                        page_shape=(8, 8), dtype=jnp.float32,
                        onboard_pages=2)
    for p in buf.append_pages(6):
        buf.write(p, jnp.ones((8, 8)))
    link = fm.snapshot()["link"]
    moved = link["tenants"]["d0"]["bytes_total"]
    assert moved > 0
    assert buf.stats()["link_wait_s"] >= 0.0
    # conservation at the device level too: wire time matches bytes
    assert link["tenants"]["d0"]["busy_s"] == pytest.approx(
        moved / link["link_bandwidth_Bps"])


def test_fabric_bw_share_journaled():
    fm, _ = make_default_fabric(pool_gib=1)
    fm.register_device(DeviceInfo("d0", DeviceClass.PCIE))
    fm.set_bw_share("d0", 2.0)
    assert any(j.op == "bw_share" and j.host_id == "d0"
               for j in fm.journal)
    assert fm.snapshot()["link"]["tenants"]["d0"]["weight"] == 2.0


# --------------------------------------------------- shared-fabric sim
@pytest.fixture(scope="module")
def sweep():
    from repro.sim import (make_ssd_model, make_workload,
                           simulate_shared_fabric)
    from repro.sim.ssd import make_schemes
    spec = make_ssd_model(5)
    scheme = make_schemes(spec)["lmb-cxl"]
    wl = make_workload("randread", n_ios=8_000)
    return {n: simulate_shared_fabric(spec, scheme, wl, n,
                                      link_bandwidth_Bps=30e9)
            for n in (1, 4, 16)}


def test_shared_fabric_saturates_at_link_bw(sweep):
    assert sweep[1].aggregate_goodput_Bps < 0.5 * 30e9   # one dev can't
    assert sweep[16].aggregate_goodput_Bps == pytest.approx(30e9, rel=0.05)
    # conservation: never above the link
    for r in sweep.values():
        assert r.aggregate_goodput_Bps <= 30e9 * 1.01


def test_shared_fabric_equal_split_within_10pct(sweep):
    r = sweep[16]
    goodputs = [d.iops * 4096 for d in r.per_device]
    assert max(goodputs) <= 1.10 * min(goodputs)
    assert r.fairness_jain > 0.99


def test_shared_fabric_p99_grows_with_contention(sweep):
    assert sweep[16].mean_p99_us > sweep[4].mean_p99_us
    assert sweep[4].mean_p99_us >= sweep[1].mean_p99_us


def test_shared_fabric_weighted_tenant_2x():
    from repro.sim import (make_ssd_model, make_workload,
                           simulate_shared_fabric)
    from repro.sim.ssd import make_schemes
    spec = make_ssd_model(5)
    scheme = make_schemes(spec)["lmb-cxl"]
    wl = make_workload("randread", n_ios=8_000)
    r = simulate_shared_fabric(spec, scheme, wl, 16,
                               link_bandwidth_Bps=30e9,
                               weights=[2.0] + [1.0] * 15)
    goodputs = [d.iops * wl.io_bytes for d in r.per_device]
    assert goodputs[0] / goodputs[1] == pytest.approx(2.0, rel=0.15)
