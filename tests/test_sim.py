"""Simulator tests: Fig 6 reproduction bands (paper §4.1)."""

import pytest

from repro.sim import make_ssd_model, make_workload, simulate
from repro.sim.ssd import Scheme, make_schemes

N_IOS = 30_000


def iops(gen, scheme_name, wl_name, hit=0.0):
    spec = make_ssd_model(gen)
    schemes = make_schemes(spec)
    s = schemes[scheme_name]
    if hit:
        s = Scheme(s.name, s.t_tier_s, s.write_through_index,
                   onboard_hit_ratio=hit)
    return simulate(spec, s, make_workload(wl_name, n_ios=N_IOS)).iops


@pytest.mark.parametrize("gen", [4, 5])
@pytest.mark.parametrize("wl", ["seqwrite", "randwrite"])
def test_writes_lmb_matches_ideal(gen, wl):
    """Fig 6: LMB-CXL and LMB-PCIe match Ideal write throughput."""
    ideal = iops(gen, "ideal", wl)
    assert iops(gen, "lmb-cxl", wl) >= 0.98 * ideal
    assert iops(gen, "lmb-pcie", wl) >= 0.98 * ideal


@pytest.mark.parametrize("gen,factor", [(4, 5.0), (5, 10.0)])
def test_writes_dftl_much_worse(gen, factor):
    """Fig 6: Ideal ~7x (Gen4) / ~20x (Gen5) over DFTL on writes."""
    assert iops(gen, "ideal", "randwrite") > \
        factor * iops(gen, "dftl", "randwrite")


def test_gen4_reads_cxl_near_ideal_pcie_mild_drop():
    """Fig 6a: LMB-CXL ≈ Ideal; LMB-PCIe −13..17 %."""
    for wl in ("seqread", "randread"):
        ideal = iops(4, "ideal", wl)
        assert iops(4, "lmb-cxl", wl) >= 0.95 * ideal
        ratio = iops(4, "lmb-pcie", wl) / ideal
        assert 0.80 <= ratio <= 0.92, ratio


def test_gen5_read_degradation_bands():
    """Fig 6b: −8 % (CXL seq), −56 % (CXL rand), −62/−70 % (PCIe)."""
    table = {
        ("lmb-cxl", "seqread"): (0.88, 0.97),
        ("lmb-cxl", "randread"): (0.40, 0.50),
        ("lmb-pcie", "seqread"): (0.33, 0.44),
        ("lmb-pcie", "randread"): (0.26, 0.34),
    }
    for (scheme, wl), (lo, hi) in table.items():
        ratio = iops(5, scheme, wl) / iops(5, "ideal", wl)
        assert lo <= ratio <= hi, (scheme, wl, ratio)


def test_reads_beat_dftl_by_order_of_magnitude():
    for gen in (4, 5):
        assert iops(gen, "lmb-pcie", "randread") > \
            10 * iops(gen, "dftl", "randread")


def test_locality_recovers_performance():
    """§4.1.2: onboard hit ratio 'considerably dismisses' the CXL cost."""
    base = iops(5, "lmb-pcie", "randread", hit=0.0)
    warm = iops(5, "lmb-pcie", "randread", hit=0.9)
    ideal = iops(5, "ideal", "randread")
    assert warm > base * 1.8
    assert warm >= 0.75 * ideal


def test_latency_ordering():
    """Per-IO latency must order ideal <= cxl <= pcie <= dftl."""
    spec = make_ssd_model(5)
    schemes = make_schemes(spec)
    wl = make_workload("randread", n_ios=N_IOS)
    lat = {n: simulate(spec, schemes[n], wl).mean_lat_us
           for n in ("ideal", "lmb-cxl", "lmb-pcie", "dftl")}
    assert lat["ideal"] <= lat["lmb-cxl"] <= lat["lmb-pcie"] <= lat["dftl"]


def test_deterministic():
    spec = make_ssd_model(4)
    schemes = make_schemes(spec)
    wl = make_workload("randread", n_ios=5000, seed=7)
    a = simulate(spec, schemes["lmb-cxl"], wl)
    b = simulate(spec, schemes["lmb-cxl"], wl)
    assert a.iops == b.iops and a.p99_lat_us == b.p99_lat_us
