"""Observability layer: span tracer, log-bucket histograms, trace
export round-trips, and span/counter reconciliation on a live system."""

import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ObsSpec, system_for
from repro.core.metrics import Metrics
from repro.obs import Histogram, SpanTracer
from repro.obs.export import (chrome_trace_events, load_trace, read_jsonl,
                              span_from_dict, span_to_dict,
                              write_chrome_trace, write_jsonl)
from repro.obs.hist import merge_all
from repro.obs.trace import Span


# ------------------------------------------------------------- histogram
class TestHistogram:
    def test_percentiles_vs_numpy(self):
        rng = np.random.default_rng(0)
        vals = rng.lognormal(mean=-6.0, sigma=1.2, size=8000)
        h = Histogram()
        h.record_many(vals)
        # bounded relative error: at most ~the bucket width (15-20%
        # at 8 buckets/decade), far tighter than a mean-only summary
        for q in (10, 50, 90, 99):
            est = h.percentile(q)
            ref = float(np.percentile(vals, q))
            assert est == pytest.approx(ref, rel=0.20), q

    def test_extremes_are_exact(self):
        h = Histogram()
        h.record_many([3e-6, 5e-4, 0.9])
        assert h.percentile(0) == 3e-6
        assert h.percentile(100) == 0.9
        assert h.min == 3e-6 and h.max == 0.9

    def test_single_value(self):
        h = Histogram()
        h.record(2.5e-3)
        for q in (0, 50, 99, 100):
            assert h.percentile(q) == pytest.approx(2.5e-3, rel=0.2)
        assert h.mean == pytest.approx(2.5e-3)

    def test_under_and_overflow(self):
        h = Histogram(lo=1e-3, hi=1e3)
        h.record(0.0)          # underflow
        h.record(1e9)          # overflow
        assert h.count == 2
        assert h.percentile(1) == 0.0       # clamped to observed min
        assert h.percentile(100) == 1e9     # exact observed max

    def test_merge_equals_combined(self):
        rng = np.random.default_rng(1)
        a_vals = rng.lognormal(-5, 1, 500)
        b_vals = rng.lognormal(-4, 1, 700)
        a, b, both = Histogram(), Histogram(), Histogram()
        a.record_many(a_vals)
        b.record_many(b_vals)
        both.record_many(np.concatenate([a_vals, b_vals]))
        merged = merge_all([a, b])
        assert merged.count == both.count
        assert np.array_equal(merged.counts, both.counts)
        assert merged.percentile(99) == both.percentile(99)

    def test_merge_layout_mismatch_raises(self):
        with pytest.raises(ValueError, match="layout"):
            Histogram().merge(Histogram(lo=1e-6, hi=1e6))

    def test_empty_snapshot(self):
        assert Histogram().snapshot()["count"] == 0
        assert Histogram().percentile(50) == 0.0


# ---------------------------------------------------------------- tracer
class TestSpanTracer:
    def test_ring_bounds_and_drop_count(self):
        tr = SpanTracer(capacity=4)
        for i in range(10):
            tr.add(f"s{i}", float(i), 1.0)
        assert len(tr) == 4
        assert tr.dropped == 6
        names = [s.name for s in tr.spans()]
        assert names == ["s6", "s7", "s8", "s9"]  # oldest-first window
        assert tr.snapshot() == {"enabled": True, "capacity": 4,
                                 "count": 4, "dropped": 6}

    def test_disabled_is_noop(self):
        tr = SpanTracer(enabled=False)
        assert tr.add("x", 0.0, 1.0) == 0
        assert tr.event("y") == 0
        cm = tr.span("z")
        with cm:
            pass
        # the disabled span() returns one shared no-op object
        assert tr.span("w") is cm
        assert len(tr) == 0 and tr.dropped == 0

    def test_parenting_via_stack(self):
        tr = SpanTracer()
        with tr.span("outer"):
            tr.event("leaf")
            with tr.span("inner"):
                tr.event("deep")
        by_name = {s.name: s for s in tr.spans()}
        assert by_name["outer"].parent_id is None
        assert by_name["leaf"].parent_id == by_name["outer"].span_id
        assert by_name["inner"].parent_id == by_name["outer"].span_id
        assert by_name["deep"].parent_id == by_name["inner"].span_id
        # nesting is reflected in time containment too
        assert by_name["outer"].t0 <= by_name["inner"].t0
        assert (by_name["inner"].t0 + by_name["inner"].dur
                <= by_name["outer"].t0 + by_name["outer"].dur + 1e-9)

    def test_tags_flow_through(self):
        tr = SpanTracer()
        tr.add("link.xfer", 0.5, 0.25, op="prefetch", tenant="t0",
               expander=3, nbytes=4096, device="d0")
        (s,) = tr.spans()
        assert (s.op, s.tenant, s.expander, s.nbytes) == (
            "prefetch", "t0", 3, 4096)
        assert s.args == {"device": "d0"}

    def test_clear_resets_epoch_and_ids(self):
        tr = SpanTracer(capacity=2)
        tr.add("a", 0.0, 1.0)
        tr.clear()
        assert len(tr) == 0 and tr.dropped == 0
        tr.add("b", 0.0, 1.0)
        assert [s.name for s in tr.spans()] == ["b"]


# --------------------------------------------------------------- export
def _sample_spans():
    return [
        Span("serve.round", 0.0, 1e-3, op="serve", span_id=1),
        Span("link.xfer", 1e-4, 5e-5, op="demand", tenant="tA",
             expander=0, nbytes=8192, span_id=2, parent_id=1,
             args={"device": "d0"}),
        Span("link.xfer", 2e-4, 7e-5, op="prefetch", expander=1,
             nbytes=4096, span_id=3, parent_id=1),
        Span("ttft", 9e-4, 0.0, op="serve", tenant="tA", span_id=4,
             parent_id=1, args={"ttft_s": 0.01}),
    ]


class TestExport:
    def test_jsonl_round_trip(self, tmp_path):
        spans = _sample_spans()
        p = tmp_path / "t.jsonl"
        write_jsonl(spans, str(p))
        back = read_jsonl(str(p))
        assert [span_to_dict(s) for s in back] == [
            span_to_dict(s) for s in spans]
        assert span_from_dict(span_to_dict(spans[1])) == spans[1]

    def test_chrome_trace_round_trip_dedupes_tracks(self, tmp_path):
        spans = _sample_spans()
        p = tmp_path / "t.json"
        write_chrome_trace(spans, str(p), extra={"note": "test"})
        with open(p) as f:
            doc = json.load(f)
        assert doc["otherData"]["note"] == "test"
        # span 2 has tenant AND expander -> emitted on both tracks
        xs = [e for e in doc["traceEvents"] if e["ph"] == "X"]
        assert len(xs) == len(spans) + 1
        # ...but load_trace returns each logical span exactly once
        back = load_trace(str(p))
        assert sorted(s.span_id for s in back) == [1, 2, 3, 4]
        by_id = {s.span_id: s for s in back}
        assert by_id[2].tenant == "tA" and by_id[2].expander == 0
        assert by_id[2].args == {"device": "d0"}
        assert by_id[2].dur == pytest.approx(5e-5)
        assert by_id[4].parent_id == 1

    def test_track_metadata(self):
        evs = chrome_trace_events(_sample_spans())
        meta = {(e["pid"], e["tid"], e["args"]["name"])
                for e in evs if e["ph"] == "M"}
        assert (1, 0, "expander 0 link") in meta
        assert (1, 1, "expander 1 link") in meta
        assert (2, 0, "tenant tA") in meta

    def test_load_trace_sniffs_jsonl(self, tmp_path):
        p = tmp_path / "one.jsonl"
        write_jsonl(_sample_spans()[:1], str(p))
        assert load_trace(str(p))[0].name == "serve.round"


# ----------------------------------------------- live-system reconciliation
def _traced_system(**kw):
    return system_for("d0", host_id="h0", pool_gib=1, page_bytes=1 << 16,
                      metrics=Metrics(), obs=ObsSpec(trace=True), **kw)


class TestReconciliation:
    def test_link_span_bytes_match_fabric_op_bytes(self):
        system = _traced_system()
        buf = system.buffer(name="kv", device_id="d0",
                            page_shape=(64, 64), dtype=jnp.float32,
                            onboard_pages=4, metrics=Metrics())
        pages = buf.append_pages(16)
        for p in pages:
            buf.write(p, jnp.full((64, 64), float(p)))
        buf.read_many(pages)                      # coalesced misses
        for p in pages[:6]:
            buf.read(p)                           # scalar faults
        by_op = {}
        for s in system.trace_spans():
            if s.name == "link.xfer":
                by_op[s.op] = by_op.get(s.op, 0) + s.nbytes
        assert by_op  # traffic definitely crossed the link
        assert by_op == system.fm.op_bytes()
        system.close()

    def test_hidden_fraction_matches_prefetch_counters(self):
        system = _traced_system()
        overlap = system.overlap_scheduler(compute_window_s=2e-3)
        n_scan, n_warm = 36, 12
        buf = system.buffer(name="pf", device_id="d0",
                            page_shape=(64, 64), dtype=jnp.float32,
                            onboard_pages=n_warm, prefetch_depth=8,
                            lmb_chunk_pages=16, overlap=overlap,
                            metrics=Metrics())
        pages = buf.append_pages(n_scan + n_warm)
        for p in pages:
            buf.write(p, jnp.full((64, 64), float(p)))
        for p in pages[n_scan:]:
            buf.release(p)              # scan streams through free slots
        w0 = buf.link_wait_s
        for p in pages[:n_scan]:        # sequential scan: prefetch hides
            system.fm.advance_links(2e-3)
            buf.note_compute_window(2e-3, observed=False)
            buf.read(p)
            buf.release(p)
        hidden = buf.prefetch_hidden_s
        exposed = buf.link_wait_s - w0
        assert hidden > 0               # the prefetcher actually ran
        pf_s = sum(s.dur for s in system.trace_spans()
                   if s.name == "link.xfer" and s.op == "prefetch")
        dm_s = sum(s.dur for s in system.trace_spans()
                   if s.name == "link.xfer" and s.op == "demand")
        # span durations ARE the modeled grant delays, so the trace
        # reproduces the buffer's hidden/exposed accounting exactly
        assert pf_s == pytest.approx(hidden, rel=1e-9)
        assert dm_s == pytest.approx(exposed + w0, rel=1e-9)
        system.close()

    def test_disabled_by_default_and_functionally_identical(self):
        def run(obs):
            system = system_for("d0", host_id="h0", pool_gib=1,
                                page_bytes=1 << 16, metrics=Metrics(),
                                obs=obs)
            buf = system.buffer(name="kv", device_id="d0",
                                page_shape=(32, 32), dtype=jnp.float32,
                                onboard_pages=4, metrics=Metrics())
            pages = buf.append_pages(12)
            for p in pages:
                buf.write(p, jnp.full((32, 32), float(p)))
            out = np.asarray(buf.read_many(pages))
            st = (system.fm.op_bytes(), system.fm.meter_calls(),
                  len(system.trace_spans()))
            system.close()
            return out, st

        out_off, (ob_off, mc_off, n_off) = run(ObsSpec())
        out_on, (ob_on, mc_on, n_on) = run(ObsSpec(trace=True))
        assert n_off == 0               # default tracer records nothing
        assert n_on > 0
        np.testing.assert_array_equal(out_off, out_on)
        assert ob_off == ob_on and mc_off == mc_on

    def test_trace_in_system_snapshot_and_export(self, tmp_path):
        system = _traced_system()
        buf = system.buffer(name="kv", device_id="d0",
                            page_shape=(32, 32), dtype=jnp.float32,
                            onboard_pages=2, metrics=Metrics())
        pages = buf.append_pages(8)
        for p in pages:
            buf.write(p, jnp.zeros((32, 32)))
        snap = system.snapshot()
        assert snap["trace"]["enabled"] is True
        assert snap["trace"]["count"] == len(system.trace_spans())
        gauges = system.metrics.snapshot()["gauges"]
        assert gauges["fm.journal_len"] == snap["journal"]["len"]
        assert gauges["fm.journal.grant"] == (
            snap["journal"]["by_op"]["grant"])
        p = tmp_path / "sys.json"
        system.export_trace(str(p))
        assert len(load_trace(str(p))) == len(system.trace_spans())
        system.close()


# ------------------------------------------------------- journal compaction
class TestJournalCompaction:
    def _held(self, fm):
        """Replay the journal into a held-block set per host."""
        held = {}
        for e in fm.journal:
            if e.op in ("grant", "regrant"):
                held.setdefault(e.host_id, set()).add(e.block_id)
            elif e.op == "release":
                held.get(e.host_id, set()).discard(e.block_id)
        return {h: s for h, s in held.items() if s}

    def test_compact_conserves_replayed_state(self):
        system = system_for("d0", host_id="h0", pool_gib=1,
                            page_bytes=4096, metrics=Metrics())
        # near-block-sized allocations: each one grants its own 256 MB
        # block, and freeing empties the block -> a release entry
        keep = [system.alloc("d0", 200 << 20) for _ in range(3)]
        for _ in range(40):             # churn: superseded grant pairs
            system.alloc("d0", 200 << 20).free()
        fm = system.fm
        before_len = fm.journal_stats()["len"]
        held_before = self._held(fm)
        removed = fm.compact()
        assert removed > 0
        assert fm.journal_stats()["len"] == before_len - removed
        assert self._held(fm) == held_before
        # the live allocations' grants survived compaction
        live_blocks = {b for s in self._held(fm).values() for b in s}
        assert live_blocks                  # `keep` still journaled
        assert fm.journal_stats()["by_op"].get("release", 0) == 0
        for h in keep:
            h.free()
        system.close()

    def test_compact_idempotent_and_stats_shape(self):
        system = system_for("d0", host_id="h0", pool_gib=1,
                            page_bytes=4096, metrics=Metrics())
        system.alloc("d0", 200 << 20).free()
        fm = system.fm
        assert fm.compact() >= 2
        assert fm.compact() == 0            # nothing left to fold
        st = fm.journal_stats()
        assert set(st) == {"len", "by_op"}
        assert st["len"] == sum(st["by_op"].values())
        system.close()
