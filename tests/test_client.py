"""Client API (ISSUE 3): LMBSystem sessions, MemoryHandle capabilities,
pluggable placement.

Pins the capability invariants: double-free and share-after-free are
typed errors (StaleHandle), failover bumps generations and kills exactly
the handles homed on the dead expander, ``with``-scoped handles release
quota, and a placement-policy swap (least-loaded → tenant-affinity)
changes block placement without touching FabricManager.
"""

import os

import pytest

from repro.core import (BLOCK_BYTES, DeviceClass, DeviceSpec, ExpanderSpec,
                        HeatAwarePolicy, HostSpec, LMBError, LMBSystem,
                        LeastLoadedPolicy, StaleHandle, SystemSpec,
                        TenantAffinityPolicy, TenantSpec, system_for)
from repro.core.api import HPA_WINDOW_BASE, PCIE_IOVA_BASE
from repro.core.placement import ExpanderView, PlacementRequest


def two_device_spec(n_expanders=1, **kw):
    return SystemSpec(
        expanders=n_expanders, pool_gib=1,
        hosts=(HostSpec("h0", page_bytes=4096),),
        devices=(DeviceSpec("ssd0"),
                 DeviceSpec("acc0", DeviceClass.CXL, spid=5)),
        **kw)


# ----------------------------------------------------------- spec/session
class TestSystemSpec:
    def test_session_owns_wiring(self):
        with LMBSystem(two_device_spec()) as system:
            assert system.host_ids == ["h0"]
            assert system.fm.device("acc0").spid == 5
            assert system.snapshot()["placement_policy"] == "least-loaded"

    def test_spec_validation(self):
        with pytest.raises(ValueError):
            SystemSpec(hosts=()).validate()
        with pytest.raises(ValueError):
            SystemSpec(devices=(DeviceSpec("c0", DeviceClass.CXL),)
                       ).validate()                     # CXL needs SPID
        with pytest.raises(ValueError):
            SystemSpec(devices=(DeviceSpec("d0", tenant="ghost"),),
                       tenants=("real",)).validate()
        with pytest.raises(ValueError):
            SystemSpec(hosts=("h0", "h0")).validate()

    def test_closed_session_refuses_allocs(self):
        system = system_for("d0", pool_gib=1)
        system.close()
        with pytest.raises(LMBError):
            system.alloc("d0", 4096)


# ------------------------------------------------------- handle lifecycle
class TestHandleLifecycle:
    def test_double_free_raises_stale(self):
        with LMBSystem(two_device_spec()) as system:
            h = system.alloc("ssd0", 4096)
            h.free()
            with pytest.raises(StaleHandle):
                h.free()

    def test_share_after_free_raises_stale(self):
        with LMBSystem(two_device_spec()) as system:
            h = system.alloc("ssd0", 4096)
            h.free()
            with pytest.raises(StaleHandle):
                h.share("acc0")

    def test_owner_free_invalidates_sharer_handles(self):
        with LMBSystem(two_device_spec()) as system:
            h = system.alloc("ssd0", 4096)
            s = h.share("acc0")
            assert s.dpid is not None          # CXL sharer sees the DPID
            h.free()
            assert s.stale
            with pytest.raises(StaleHandle):
                s.expander()

    def test_share_is_deduplicated_per_device(self):
        """One live capability per (allocation, device): re-sharing to
        the same device returns the existing handle, so no alias can be
        left dangling by freeing its twin."""
        with LMBSystem(two_device_spec()) as system:
            h = system.alloc("ssd0", 4096)
            s1 = h.share("acc0")
            s2 = h.share("acc0")
            assert s1 is s2
            assert h.share("ssd0") is h        # owner's own device too
            s1.free()
            s3 = h.share("acc0")               # fresh grant after free
            assert s3 is not s1 and not s3.stale

    def test_session_registry_drops_freed_handles(self):
        system = system_for("d0", pool_gib=1)
        handles = [system.alloc("d0", 4096) for _ in range(8)]
        for h in handles:
            h.free()
        assert len(system._handles) == 0       # no dead-handle buildup
        assert system.live_handles() == []
        system.close()

    def test_sharer_free_drops_only_its_mapping(self):
        with LMBSystem(two_device_spec()) as system:
            h = system.alloc("ssd0", 4096)
            s = h.share("acc0")
            s.free()
            assert not h.stale                 # owner unaffected
            system.host().check_access("ssd0", h.mmid)

    def test_with_scope_autofree_releases_quota(self):
        with LMBSystem(two_device_spec()) as system:
            fm = system.fm
            with system.alloc("ssd0", 1 << 20) as h:
                assert fm.held_bytes("h0") == BLOCK_BYTES
                assert h.nbytes >= 1 << 20
            # exiting the handle scope freed the region AND the block
            assert fm.held_bytes("h0") == 0
            assert system.live_handles() == []

    def test_session_close_frees_leaks(self):
        system = LMBSystem(two_device_spec())
        system.alloc("ssd0", 4096)             # never freed by the caller
        assert system.fm.held_bytes("h0") == BLOCK_BYTES
        system.close()
        assert system.fm.held_bytes("h0") == 0

    def test_session_close_releases_buffer_footprint(self):
        jnp = pytest.importorskip("jax.numpy")
        system = LMBSystem(two_device_spec())
        buf = system.buffer(name="b", device_id="ssd0",
                            page_shape=(8, 8), dtype=jnp.float32,
                            onboard_pages=2, lmb_chunk_pages=4)
        for p in buf.append_pages(8):          # spills into the LMB tier
            buf.write(p, jnp.ones((8, 8)))
        assert system.fm.held_bytes("h0") > 0
        system.close()                         # buffers drained too
        assert system.fm.held_bytes("h0") == 0
        buf.check_invariants()
        # a closed buffer cannot silently re-acquire quota: growth into
        # the LMB tier is refused (degraded, onboard-only)
        from repro.core import OutOfMemory
        with pytest.raises(OutOfMemory):
            for p in buf.append_pages(8):
                buf.write(p, jnp.ones((8, 8)))
        assert system.fm.held_bytes("h0") == 0
        # and the FM no longer holds the closed buffer as a listener
        assert buf._on_failover not in system.fm._failover_listeners


# ----------------------------------------------------- failover staleness
class TestFailoverStaleness:
    def test_stale_after_inject_failure(self):
        system = system_for("d0", pool_gib=1, n_expanders=2)
        h0 = system.alloc("d0", 4096, expander_id=0)
        h1 = system.alloc("d0", 4096, expander_id=1)
        system.inject_failure(0)
        assert h0.stale and not h1.stale       # only the dead expander's
        with pytest.raises(StaleHandle) as ei:
            h0.expander()
        assert "generation" in str(ei.value)
        # survivor still fully operational
        assert h1.expander() == 1
        h1.free()

    def test_generation_bump_is_per_expander(self):
        system = system_for("d0", pool_gib=1, n_expanders=2)
        host = system.host()
        system.inject_failure(1)
        assert host.generation_of(1) == 1
        assert host.generation_of(0) == 0

    def test_with_scope_tolerates_failover(self):
        system = system_for("d0", pool_gib=1)
        with system.alloc("d0", 4096):
            system.inject_failure()            # kills the only expander
        # __exit__ must not raise on the now-stale handle


# ---------------------------------------------------------- Table-2 verbs
class TestAgnosticVerbs:
    def test_alloc_dispatches_on_device_class(self):
        with LMBSystem(two_device_spec()) as system:
            pcie = system.alloc("ssd0", 4096)
            cxl = system.alloc("acc0", 4096)
            assert pcie.dpid is None and cxl.dpid is not None
            # same call, per-class addressing (no lmb_pcie_/lmb_cxl_ split)
            assert pcie.bus_addr != pcie.hpa
            assert cxl.bus_addr == cxl.hpa

    def test_pcie_iova_window_is_identity_mapped(self):
        """Satellite: PCIe devices get a distinct identity-mapped IOVA
        window; CXL devices address with the HPA."""
        with LMBSystem(two_device_spec()) as system:
            h = system.alloc("ssd0", 4096)
            assert h.bus_addr - PCIE_IOVA_BASE == h.hpa - HPA_WINDOW_BASE
            assert PCIE_IOVA_BASE != HPA_WINDOW_BASE

    def test_table2_shims(self):
        """The Table-2 names survive as shims over the agnostic verbs —
        every call works, warns DeprecationWarning, and still enforces
        class membership (the one behavior the generic verbs dropped)."""
        with LMBSystem(two_device_spec()) as system:
            host = system.host()
            with pytest.warns(DeprecationWarning, match="lmb_pcie_alloc"):
                a = host.lmb_pcie_alloc("ssd0", 4096)
            with pytest.warns(DeprecationWarning, match="lmb_pcie_share"):
                s = host.lmb_pcie_share("ssd0", a.mmid, "acc0")
            assert s.dpid is not None
            with pytest.warns(DeprecationWarning, match="lmb_cxl_free"):
                host.lmb_cxl_free("acc0", a.mmid)
            with pytest.warns(DeprecationWarning, match="lmb_pcie_free"):
                host.lmb_pcie_free("ssd0", a.mmid)
            # class checks preserved: the shim (and only the shim) rejects
            # a device of the other class before dispatching
            with pytest.warns(DeprecationWarning):
                with pytest.raises(LMBError):
                    host.lmb_cxl_alloc("ssd0", 4096)
            with pytest.warns(DeprecationWarning):
                with pytest.raises(LMBError):
                    host.lmb_pcie_alloc("acc0", 4096)
            with pytest.warns(DeprecationWarning, match="lmb_cxl_alloc"):
                c = host.lmb_cxl_alloc("acc0", 4096)
            with pytest.warns(DeprecationWarning, match="lmb_cxl_share"):
                host.lmb_cxl_share("acc0", c.mmid, "ssd0")

    def test_no_in_repo_shim_callers(self):
        """No code in the repo calls the deprecated Table-2 shims except
        their definitions and this test file (the deprecation is real:
        everything in-tree went through the migration)."""
        import re
        root = os.path.join(os.path.dirname(__file__), "..")
        allowed = {
            os.path.normpath(os.path.join(root, "src/repro/core/api.py")),
            os.path.normpath(os.path.abspath(__file__)),
        }
        pat = re.compile(r"\.lmb_(pcie|cxl)_(alloc|free|share)\(")
        offenders = []
        for dirpath, dirnames, filenames in os.walk(os.path.normpath(root)):
            dirnames[:] = [d for d in dirnames
                           if d not in (".git", "__pycache__", ".pytest_cache")]
            for fn in filenames:
                if not fn.endswith(".py"):
                    continue
                path = os.path.normpath(os.path.join(dirpath, fn))
                if path in allowed:
                    continue
                with open(path, encoding="utf-8", errors="ignore") as f:
                    if pat.search(f.read()):
                        offenders.append(os.path.relpath(path, root))
        assert not offenders, f"deprecated shim callers: {offenders}"

    def test_bind_host_idempotent(self):
        """Satellite: re-binding is a no-op and never resets a quota."""
        system = system_for("d0", pool_gib=1)
        fm = system.fm
        fm.set_quota("host0", BLOCK_BYTES)
        fm.bind_host("host0")                      # idempotent re-bind
        assert fm.snapshot()["hosts"]["host0"] == BLOCK_BYTES
        binds = [j for j in fm.journal if j.op == "bind"]
        assert len(binds) == 1


# ------------------------------------------------------ placement policies
class TestPlacementPolicies:
    def _views(self, *triples):
        return [ExpanderView(expander_id=e, free_bytes=f, utilization=u)
                for e, f, u in triples]

    def test_least_loaded_prefers_cool_then_roomy(self):
        p = LeastLoadedPolicy()
        views = self._views((0, 100, 0.9), (1, 50, 0.1), (2, 500, 0.1))
        assert p.choose(PlacementRequest(), views) == 2
        assert p.choose(PlacementRequest(), []) is None

    def test_heat_aware_packs_by_capacity_when_cool(self):
        p = HeatAwarePolicy(hot_threshold=0.5)
        cool = self._views((0, 100, 0.2), (1, 500, 0.3))
        assert p.choose(PlacementRequest(), cool) == 1   # most free bytes
        hot = self._views((0, 100, 0.9), (1, 500, 0.7))
        assert p.choose(PlacementRequest(), hot) == 1    # least loaded

    def test_tenant_affinity_sticky_round_robin(self):
        p = TenantAffinityPolicy()
        views = self._views((0, 100, 0.0), (1, 100, 0.0))
        a = p.choose(PlacementRequest(tenant="a"), views)
        b = p.choose(PlacementRequest(tenant="b"), views)
        assert {a, b} == {0, 1}
        # sticky on repeat, even when the other link is idler
        views2 = self._views((0, 100, 0.9), (1, 100, 0.9))
        assert p.choose(PlacementRequest(tenant="a"), views2) == a
        assert p.assignments == {"a": a, "b": b}

    def test_policy_swap_without_touching_fabric(self):
        """Acceptance: least-loaded → tenant-affinity is a SystemSpec
        change only; FabricManager is untouched."""

        def build(placement):
            return LMBSystem(SystemSpec(
                expanders=(ExpanderSpec(gib=1), ExpanderSpec(gib=1)),
                hosts=(HostSpec("h0", page_bytes=4096),),
                devices=(DeviceSpec("gold0", tenant="gold"),
                         DeviceSpec("gold1", tenant="gold"),
                         DeviceSpec("best0", tenant="besteffort")),
                tenants=(TenantSpec("gold", preferred_expander=0),
                         TenantSpec("besteffort", preferred_expander=1)),
                placement=placement))

        # tenant-affinity: each tenant's blocks stay on its home expander
        with build("tenant-affinity") as system:
            g0 = system.alloc("gold0", BLOCK_BYTES // 2)
            g1 = system.alloc("gold1", BLOCK_BYTES // 2)
            b0 = system.alloc("best0", BLOCK_BYTES // 2)
            assert g0.expander() == 0 and g1.expander() == 0
            assert b0.expander() == 1
            assert system.snapshot()["placement_policy"] == "tenant-affinity"

        # least-loaded (default): the same allocs spread for balance —
        # the second gold alloc lands on the emptier expander instead
        with build("least-loaded") as system:
            system.alloc("gold0", BLOCK_BYTES // 2)
            g1 = system.alloc("gold1", BLOCK_BYTES)
            assert g1.expander() == 1

    def test_affinity_falls_back_when_home_full(self):
        spec = SystemSpec(
            expanders=(ExpanderSpec(gib=1), ExpanderSpec(gib=1)),
            hosts=(HostSpec("h0", page_bytes=4096),),
            devices=(DeviceSpec("d0", tenant="t"),),
            tenants=(TenantSpec("t", preferred_expander=0),),
            placement="tenant-affinity")
        with LMBSystem(spec) as system:
            handles = [system.alloc("d0", BLOCK_BYTES)
                       for _ in range(4)]     # 1 GiB = 4 blocks per exp
            homes = [h.expander() for h in handles]
            assert homes == [0, 0, 0, 0]      # affinity while room
            spill = system.alloc("d0", BLOCK_BYTES)
            assert spill.expander() == 1      # graceful spill, no OOM
